"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,H,KV,d", [
    (2, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 4, 1, 32),      # MQA
    (1, 512, 2, 2, 128),     # long-ish, wide head
    (3, 64, 6, 3, 16),       # odd sizes (block fallback)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, KV, d, dtype):
    q = _rand((B, S, H, d), dtype)
    k = _rand((B, S, KV, d), dtype)
    v = _rand((B, S, KV, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    expect = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), causal=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * 10)


def test_flash_attention_noncausal():
    q = _rand((2, 128, 4, 32), jnp.float32)
    k = _rand((2, 128, 2, 32), jnp.float32)
    v = _rand((2, 128, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    expect = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


def test_flash_attention_matches_model_sdpa():
    """Kernel agrees with the model zoo's attention lowering."""
    from repro.models.attention import sdpa
    q = _rand((2, 128, 8, 64), jnp.float32)
    k = _rand((2, 128, 2, 64), jnp.float32)
    v = _rand((2, 128, 2, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v, causal=True)),
        np.asarray(sdpa(q, k, v, causal=True)), rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------- #
# Mamba2 SSD scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,g,p,n,chunk", [
    (2, 128, 4, 1, 32, 64, 32),
    (1, 256, 8, 2, 64, 128, 64),
    (2, 64, 2, 1, 16, 32, 64),    # chunk > s (falls back to s)
    (1, 96, 3, 1, 32, 16, 32),    # non-pow2 heads
])
def test_ssd_scan_matches_sequential_ref(b, s, h, g, p, n, chunk):
    x = _rand((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, h), jnp.float32)
    B = _rand((b, s, g, n), jnp.float32)
    C = _rand((b, s, g, n), jnp.float32)
    y, state = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, st_ref = ref.ssd_scan_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_chunked_form():
    """Kernel agrees with the model zoo's chunked SSD (different algorithm
    again: dual quadratic chunks vs the kernel's carried-state loop)."""
    from repro.models.ssm import ssd_scan_ref as model_ssd
    b, s, h, g, p, n = 2, 128, 4, 1, 32, 64
    x = _rand((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, h), jnp.float32)
    B = _rand((b, s, g, n), jnp.float32)
    C = _rand((b, s, g, n), jnp.float32)
    y_k, st_k = ops.ssd_scan(x, dt, A, B, C, chunk=32)
    y_m, st_m = model_ssd(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(4, 64, 256), (128, 512), (3, 5, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    w = _rand((shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, w)
    expect = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_matches_model_layer():
    from repro.models.common import rms_norm
    x = _rand((8, 128), jnp.float32)
    w = _rand((128,), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)),
                               np.asarray(rms_norm(x, w)), rtol=1e-5,
                               atol=1e-5)
