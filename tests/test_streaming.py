"""Streaming arrival-path equivalence + the trace workload family.

The PR-7 contract: a streamed run is *bit-identical* to the materialized
run — same drops, same migrations, same completion times, same
``summary()`` — for every window size, with ``retain_requests`` on or
off.  These tests pin that contract across scenario families, engines,
and the solo/batched drivers, plus the window-edge cases that only the
refill path exercises (arrivals exactly at chunk boundaries, RAN burst
ties, a drained heap mid-gap, truncation mid-window).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.sim import Simulator, make_scenario
from repro.sim.engine import DeadlineAwareAllocation, StaticPlacement
from repro.sim.scenarios.workload import workload_for, workload_stream_for
from repro.sim.stream import ArrivalStream, ListStream, as_arrival_stream
from repro.sim.types import RequestClass

STREAM_FAMILIES = ("paper", "flash-crowd", "heavy-tail")


def _canon(summary):
    return {k: None if isinstance(v, float) and math.isnan(v) else v
            for k, v in summary.items()}


def _fingerprint(res):
    return (_canon(res.summary()), res.n_events, res.infeasible_events,
            sorted(res.dropped), res.truncated,
            [(r.rid, r.finish, r.target_sid) for r in res.requests],
            [(t, a.sid, a.src, a.dst) for t, a in res.migrations])


def _run(sc, workload, engine="numpy", retain=True, max_events=5_000_000):
    sim = Simulator(sc, engine=engine)
    return sim.run(workload, StaticPlacement(), DeadlineAwareAllocation(),
                   retain_requests=retain, max_events=max_events)


# --------------------------------------------------------------------------- #
# streamed == materialized: families x engines x {solo, batched}
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("family", STREAM_FAMILIES)
@pytest.mark.parametrize("engine", ("numpy", "jax"))
def test_streamed_matches_materialized_solo(family, engine):
    if engine == "jax":
        pytest.importorskip("jax")
    sc = make_scenario(family, seed=0)
    stream = workload_stream_for(sc, seed=1, n_ai_requests=150)
    a = _run(sc, stream.materialize(), engine=engine)
    b = _run(sc, stream.rechunked(48), engine=engine)
    assert _fingerprint(a) == _fingerprint(b)


@pytest.mark.parametrize("family", STREAM_FAMILIES)
def test_streamed_matches_materialized_batched(family):
    sc = make_scenario(family, seed=0)
    seeds = (0, 1, 2)
    srcs = [workload_stream_for(sc, seed=s, n_ai_requests=120)
            for s in seeds]
    sim = Simulator(sc)
    a = sim.run_batch([s.materialize() for s in srcs],
                      lambda b: StaticPlacement(),
                      lambda b: DeadlineAwareAllocation())
    b = sim.run_batch([s.rechunked(33) for s in srcs],
                      lambda b: StaticPlacement(),
                      lambda b: DeadlineAwareAllocation())
    assert [_fingerprint(r) for r in a] == [_fingerprint(r) for r in b]


def test_window_size_never_changes_outcomes():
    """The window is a memory knob: every size yields the same run."""
    sc = make_scenario("paper", seed=0)
    src = workload_stream_for(sc, seed=2, n_ai_requests=120)
    ref = _fingerprint(_run(sc, src.materialize()))
    for window in (1, 7, 64, 10_000):
        assert _fingerprint(_run(sc, src.rechunked(window))) == ref, \
            f"window={window}"


def test_raw_list_keeps_legacy_scan_horizon():
    """A plain request list (no metadata) keeps the pre-stream behavior:
    the epoch schedule derives from ``max(r.arrival)`` instead of the
    analytic horizon, so ``n_events`` may differ from a metadata-carrying
    stream by trailing empty epochs — every discrete outcome (summary,
    drops, finishes, migrations) must still be identical."""
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=2, n_ai_requests=120)
    a = _fingerprint(_run(sc, stream.to_list()))
    b = _fingerprint(_run(sc, stream.rechunked(40)))
    assert a[0] == b[0]            # summary
    assert a[3:] == b[3:]          # drops, truncation, finishes, migrations


# --------------------------------------------------------------------------- #
# window-boundary semantics only the refill path exercises
# --------------------------------------------------------------------------- #
def test_arrivals_exactly_at_window_edges():
    """Duplicate arrival times straddling a chunk boundary must pop in
    emit order — the refill's ``>=`` comparison keeps pulling through
    exact ties split across chunks."""
    sc = make_scenario("paper", seed=0)
    reqs, _ = workload_for(sc, seed=3, n_ai_requests=120)
    # forge exact ties at positions 9/10/11 and 19/20 (window=10 puts the
    # tie on both sides of the first two refill edges)
    reqs = [dataclasses.replace(r) for r in reqs]
    for i in (9, 10, 11):
        reqs[i] = dataclasses.replace(reqs[i], arrival=reqs[9].arrival)
    for i in (19, 20):
        reqs[i] = dataclasses.replace(reqs[i], arrival=reqs[19].arrival)
    bulk = ListStream([dataclasses.replace(r) for r in reqs])
    windowed = ListStream(reqs, window=10)
    assert _fingerprint(_run(sc, bulk)) == _fingerprint(_run(sc, windowed))


def test_ran_burst_ties_with_window_one():
    """RAN bursts arrive at ``base + b * 1e-5`` offsets: window=1 forces a
    refill between every burst member, the harshest tie-ordering case."""
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=4, n_ai_requests=100)
    n_ran = sum(1 for r in stream.to_list()
                if r.cls is RequestClass.RAN)
    assert n_ran > 10   # the scenario really has RAN bursts to order
    assert _fingerprint(_run(sc, stream.materialize())) == \
        _fingerprint(_run(sc, stream.rechunked(1)))


def test_refill_across_drained_heap_gap():
    """A long arrival gap drains the heap mid-run; the next window must
    still load (refill triggers on heap-top >= loaded_until, with an
    empty heap treated as +inf)."""
    sc = make_scenario("paper", seed=0)
    reqs, _ = workload_for(sc, seed=5, n_ai_requests=60)
    reqs = sorted((dataclasses.replace(r) for r in reqs),
                  key=lambda r: r.arrival)
    # push the last third of the trace far past the busy period
    gap = [dataclasses.replace(r, arrival=r.arrival + 500.0)
           for r in reqs[40:]]
    trace = reqs[:40] + gap
    bulk = ListStream([dataclasses.replace(r) for r in trace])
    windowed = ListStream(trace, window=16)
    a, b = _run(sc, bulk), _run(sc, windowed)
    assert _fingerprint(a) == _fingerprint(b)
    assert all(r.finish >= 500.0 for r in a.requests[40:])  # gap really ran


def test_max_events_truncation_mid_window():
    """Truncation with unloaded windows: the never-loaded tail still
    counts into ``n_requests`` (drained at result time) and the
    accumulator books it as violated — identically for both paths."""
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=6, n_ai_requests=200)
    n_total = len(stream.to_list())
    a = _run(sc, stream.materialize(), max_events=300)
    b = _run(sc, stream.rechunked(25), max_events=300, retain=False)
    assert a.truncated and b.truncated
    assert a.n_events == b.n_events
    assert _canon(a.summary()) == _canon(b.summary())
    assert a.n_requests == b.n_requests == n_total
    assert a.violation_counts() == b.violation_counts()


# --------------------------------------------------------------------------- #
# retain_requests=False: summaries from the streaming accumulators
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("family", STREAM_FAMILIES)
def test_retain_requests_off_identical_summary(family):
    sc = make_scenario(family, seed=0)
    stream = workload_stream_for(sc, seed=1, n_ai_requests=150)
    ref = _run(sc, stream.materialize())
    res = _run(sc, stream.rechunked(40), retain=False)
    assert len(res.requests) == 0
    assert res.n_requests == len(ref.requests)
    assert _canon(res.summary()) == _canon(ref.summary())
    assert res.violation_counts() == ref.violation_counts()
    assert res.fulfillment() == ref.fulfillment()


def test_summary_nan_semantics_without_ran():
    """A trace with no RAN arrivals keeps the NaN (absent-class) summary
    entries under both the request-scan and accumulator paths."""
    sc = make_scenario("trace", n_ai_requests=150)
    stream = workload_stream_for(sc, seed=0)
    res = _run(sc, stream, retain=False)
    assert math.isnan(res.summary()["ran"])
    assert "RAN" not in res.fulfillment()
    assert res.violation_counts()["ran"] == (0, 0)


def test_obs_trace_counters_reconcile_streamed():
    """obs arrival/completion/drop counters must reconcile exactly against
    the streaming accumulators when no request list is retained."""
    from repro.obs import ObsConfig

    sc = make_scenario("flash-crowd", seed=0)
    stream = workload_stream_for(sc, seed=1, n_ai_requests=300, window=64)
    sim = Simulator(sc, drop_expired=True)
    res = sim.run(stream, StaticPlacement(), DeadlineAwareAllocation(),
                  retain_requests=False, obs=ObsConfig(trace=True))
    counts = res.trace.counts(0)
    assert res.dropped, "flash-crowd should drop; workload too small"
    assert counts["arrival"] == res.n_requests
    assert counts["drop"] == len(res.dropped)
    assert counts["completion"] == res.n_requests - len(res.dropped)


# --------------------------------------------------------------------------- #
# the ArrivalStream abstraction itself
# --------------------------------------------------------------------------- #
def test_stream_is_restartable_and_deterministic():
    sc = make_scenario("heavy-tail", seed=0)
    stream = workload_stream_for(sc, seed=7, n_ai_requests=100)
    first = [(r.rid, r.arrival, r.kv_bytes) for r in stream.to_list()]
    second = [(r.rid, r.arrival, r.kv_bytes) for r in stream.to_list()]
    assert first == second


def test_rechunked_preserves_content_and_metadata():
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=0, n_ai_requests=80)
    re = stream.rechunked(13)
    assert re.horizon == stream.horizon
    assert [r.rid for c in re.chunks() for r in c] == \
        [r.rid for r in stream.to_list()]
    assert all(len(c) <= 13 for c in re.chunks())


def test_materialize_keeps_analytic_horizon_and_clones_lazily():
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=0, n_ai_requests=80)
    mat = stream.materialize()
    assert mat.horizon == stream.horizon
    # the engine mutates finish/target on the requests it sees; a cloned
    # ListStream must leave the backing list untouched across replays
    a = _run(sc, mat)
    assert all(r.finish < 0.0 for r in mat.to_list())  # -1.0 = never run
    b = _run(sc, mat)
    assert _fingerprint(a) == _fingerprint(b)


def test_as_arrival_stream_passthrough_and_wrap():
    sc = make_scenario("paper", seed=0)
    stream = workload_stream_for(sc, seed=0, n_ai_requests=50)
    assert as_arrival_stream(stream) is stream
    reqs = stream.to_list()
    wrapped = as_arrival_stream(reqs)
    assert isinstance(wrapped, ArrivalStream)
    # legacy list input: horizon falls back to the arrival scan
    assert wrapped.horizon == max(r.arrival for r in reqs)


# --------------------------------------------------------------------------- #
# the trace workload family (CSV/JSONL replay + built-in synthetic)
# --------------------------------------------------------------------------- #
def test_trace_builtin_synthetic_matches_written_csv(tmp_path):
    """file='' replays the same rows the CSV writer emits, so a written
    trace at the same (n, seed) must reproduce the built-in run."""
    from repro.sim.tracefile import write_synthetic_trace

    path = tmp_path / "trace.csv"
    write_synthetic_trace(str(path), 300, seed=5)
    sc_file = make_scenario("trace", file=str(path))
    sc_builtin = make_scenario("trace", n_ai_requests=300)
    a = _run(sc_file, workload_stream_for(sc_file, seed=5))
    b = _run(sc_builtin, workload_stream_for(sc_builtin, seed=5))
    assert _fingerprint(a) == _fingerprint(b)


def test_trace_jsonl_matches_csv(tmp_path):
    from repro.sim.tracefile import write_synthetic_trace

    csv_p, jsonl_p = tmp_path / "t.csv", tmp_path / "t.jsonl"
    write_synthetic_trace(str(csv_p), 200, seed=1)
    write_synthetic_trace(str(jsonl_p), 200, seed=1)
    sc_a = make_scenario("trace", file=str(csv_p))
    sc_b = make_scenario("trace", file=str(jsonl_p))
    a = _run(sc_a, workload_stream_for(sc_a, seed=1))
    b = _run(sc_b, workload_stream_for(sc_b, seed=1))
    assert _fingerprint(a) == _fingerprint(b)


def test_trace_window_and_retain_invariance():
    sc = make_scenario("trace", n_ai_requests=250)
    ref = _run(sc, workload_stream_for(sc, seed=3))
    for window in (1, 17, 4096):
        res = _run(sc, workload_stream_for(sc, seed=3, window=window),
                   retain=False)
        assert _canon(res.summary()) == _canon(ref.summary())
        assert res.n_requests == ref.n_requests
        assert res.violation_counts() == ref.violation_counts()


def test_trace_seed_changes_realization():
    sc = make_scenario("trace", n_ai_requests=200)
    a = workload_stream_for(sc, seed=0).to_list()
    b = workload_stream_for(sc, seed=1).to_list()
    assert [r.arrival for r in a] != [r.arrival for r in b]


def test_trace_speedup_compresses_arrivals():
    sc1 = make_scenario("trace", n_ai_requests=200)
    sc2 = make_scenario("trace", n_ai_requests=200, speedup=2.0)
    a = workload_stream_for(sc1, seed=0).to_list()
    b = workload_stream_for(sc2, seed=0).to_list()
    np.testing.assert_allclose([r.arrival for r in b],
                               [r.arrival / 2.0 for r in a], rtol=1e-12)


def test_trace_class_map_relabels(tmp_path):
    from repro.sim.tracefile import parse_class_map

    assert parse_class_map("chat=small,batch=large") == \
        {"chat": "small", "batch": "large"}
    with pytest.raises(ValueError):
        parse_class_map("chat=медиум")

    path = tmp_path / "labels.csv"
    path.write_text("arrival,cls,prompt_tokens,output_tokens\n"
                    "0.5,chat,120,40\n1.0,batch,900,300\n"
                    "1.5,chat,80,20\n")
    sc = make_scenario("trace", file=str(path),
                       class_map="chat=small,batch=large")
    reqs = workload_stream_for(sc, seed=0).to_list()
    assert [r.cls for r in reqs] == [RequestClass.SMALL_AI,
                                     RequestClass.LARGE_AI,
                                     RequestClass.SMALL_AI]


def test_trace_rejects_unsorted_arrivals(tmp_path):
    path = tmp_path / "bad.csv"
    path.write_text("arrival,cls,prompt_tokens,output_tokens\n"
                    "2.0,small,10,10\n1.0,small,10,10\n")
    sc = make_scenario("trace", file=str(path))
    with pytest.raises(ValueError, match="sorted|nondecreasing"):
        workload_stream_for(sc, seed=0).to_list()


def test_trace_bounded_memory_replay():
    """A windowed trace replay with retain_requests=False keeps no
    per-request state: the result carries counts, not lists."""
    sc = make_scenario("trace", n_ai_requests=2000)
    stream = workload_stream_for(sc, seed=0, window=256)
    res = _run(sc, stream, retain=False)
    assert res.requests == [] and res.n_requests == 2000
    counts = res.violation_counts()
    assert counts["overall"][0] == 2000
    assert counts["large_ai"][0] + counts["small_ai"][0] == 2000


# --------------------------------------------------------------------------- #
# spec plumbing: stream/window are memory knobs, not identity
# --------------------------------------------------------------------------- #
def test_spec_identity_hash_ignores_stream_and_window():
    from repro.exp import ExperimentSpec, parse_methods, parse_scenarios

    base = dict(methods=parse_methods("haf-static"),
                scenarios=parse_scenarios("paper"), seeds=(0,))
    a = ExperimentSpec(**base)
    b = ExperimentSpec(**base, stream=True, window=512)
    assert a.identity_hash() == b.identity_hash()
    assert a.spec_hash() != b.spec_hash()


def test_spec_identity_hash_ignores_trace_window_param():
    from repro.exp import ExperimentSpec, parse_methods, parse_scenarios

    mk = lambda s: ExperimentSpec(methods=parse_methods("haf-static"),
                                  scenarios=parse_scenarios(s), seeds=(0,))
    a = mk("trace(n_ai_requests=200, window=100)")
    b = mk("trace(n_ai_requests=200, window=9000)")
    c = mk("trace(n_ai_requests=300, window=100)")
    assert a.identity_hash() == b.identity_hash()
    assert a.identity_hash() != c.identity_hash()


def test_sweep_rows_identical_streamed():
    """run_sweep with stream=True must produce the same result rows."""
    import dataclasses as dc

    from repro.eval import SweepSpec, run_sweep

    spec = SweepSpec(methods=("haf-static",), scenarios=("paper",),
                     seeds=(0, 1), n_ai_requests=120, workers=1)
    rows_m = [r for r in run_sweep(spec) if r is not None]
    rows_s = [r for r in run_sweep(dc.replace(spec, stream=True,
                                              window=50)) if r is not None]
    key = lambda r: (r["method"], r["scenario"], r["seed"])  # noqa: E731
    for m, s in zip(sorted(rows_m, key=key), sorted(rows_s, key=key)):
        assert key(m) == key(s)
        assert m["overall"] == s["overall"]
        assert m["n_events"] == s["n_events"]
        assert m["n_requests"] == s["n_requests"]
