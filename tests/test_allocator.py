"""Property tests for the closed-form deadline-aware allocator (Eq. 13–19).

The paper's claim is that the active-set closed form IS the argmin of the
convex problem (16).  We certify:
  * KKT optimality vs a numeric projected-gradient solve,
  * the capacity and floor constraints as invariants under random inputs,
  * exact agreement between the JAX, NumPy, and Pallas implementations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                    # hypothesis is an optional test dep:
    import hypothesis.strategies as st  # without it only the property-based
    from hypothesis import given, settings   # tests below are skipped
except ImportError:
    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

from repro.core import allocator
from repro.core.allocator_np import active_set_np, solve_resource_np
from repro.kernels import ops as kops

S = 12


def _rand_inputs(seed, feasible_floors=True):
    rng = np.random.default_rng(seed)
    psi = np.where(rng.random(S) < 0.8, rng.uniform(0, 1e14, S), 0.0)
    omega = np.where(psi > 0, rng.uniform(0.1, 1e3, S), 0.0)
    cap = rng.uniform(5e13, 3e14)
    floors = np.where(rng.random(S) < 0.4, rng.uniform(0, cap / S, S), 0.0)
    if not feasible_floors:
        floors = floors * 20.0
    mask = rng.random(S) < 0.9
    return psi, omega, floors, cap, mask


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), feas=st.booleans())
def test_capacity_and_floor_invariants(seed, feas):
    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    alloc = np.asarray(res.alloc)
    # capacity: Σ alloc ≤ cap (float32 tolerance)
    assert alloc.sum() <= cap * (1 + 1e-5) + 1e3
    # non-resident instances get nothing
    assert np.all(alloc[~mask] == 0)
    # floors respected whenever they are jointly feasible
    if bool(res.feasible):
        f = np.where(mask, floors, 0.0)
        assert np.all(alloc + cap * 1e-5 + 1e3 >= f)
    # non-negative
    assert np.all(alloc >= 0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_closed_form_matches_numeric_convex_solve(seed):
    """The active-set result attains the numeric optimum of Eq. 16."""
    psi, omega, floors, cap, mask = _rand_inputs(seed, True)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    x_num = allocator.solve_numeric(jnp.asarray(psi), jnp.asarray(omega),
                                    jnp.asarray(floors), jnp.asarray(cap),
                                    jnp.asarray(mask))
    f_closed = float(allocator.objective(res.alloc, jnp.asarray(psi),
                                         jnp.asarray(omega),
                                         jnp.asarray(mask)))
    f_num = float(allocator.objective(x_num, jnp.asarray(psi),
                                      jnp.asarray(omega), jnp.asarray(mask)))
    # closed form must be at least as good as the numeric solve
    assert f_closed <= f_num * (1 + 5e-3) + 1e-9


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), feas=st.booleans())
def test_jax_equals_numpy(seed, feas):
    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    a_np, f_np, _ = solve_resource_np(psi, omega, floors, float(cap), mask)
    np.testing.assert_allclose(np.asarray(res.alloc), a_np, rtol=1e-4,
                               atol=cap * 1e-5)
    assert bool(res.feasible) == bool(f_np)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pallas_kernel_equals_oracle(seed):
    rng = np.random.default_rng(seed)
    N = 4
    psi = rng.uniform(0, 1e14, (N, S))
    omega = rng.uniform(0, 100, (N, S))
    cap = rng.uniform(5e13, 2e14, N)
    floors = np.where(rng.random((N, S)) < 0.3,
                      rng.uniform(0, 2e13, (N, S)), 0.0)
    mask = rng.random((N, S)) < 0.9
    al, fe, pin = kops.alloc_active_set(
        jnp.asarray(psi), jnp.asarray(omega), jnp.asarray(floors),
        jnp.asarray(cap), jnp.asarray(mask))
    for n in range(N):
        a_np, f_np, _ = solve_resource_np(psi[n], omega[n], floors[n],
                                          float(cap[n]), mask[n])
        np.testing.assert_allclose(np.asarray(al[n]), a_np, rtol=1e-4,
                                   atol=cap[n] * 1e-5)
        assert bool(fe[n]) == bool(f_np)


def test_sqrt_proportionality():
    """Unfloored instances follow g ∝ √(ωΨ) exactly (Eq. 17)."""
    psi = np.array([1e13, 4e13, 9e13, 0.0])
    omega = np.array([1.0, 1.0, 1.0, 0.0])
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.zeros(4), jnp.asarray(1e14),
                                   jnp.ones(4, bool))
    a = np.asarray(res.alloc)
    w = np.sqrt(psi * omega)
    np.testing.assert_allclose(a[:3] / a[:3].sum(), w[:3] / w[:3].sum(),
                               rtol=1e-5)
    assert a[3] == 0.0
    np.testing.assert_allclose(a.sum(), 1e14, rtol=1e-5)


def test_floor_clipping_redistributes():
    """A pinned instance keeps its floor; the rest re-share (Eq. 18–19)."""
    psi = np.array([1e10, 5e13, 5e13])          # inst 0: tiny work, big floor
    omega = np.ones(3)
    floors = np.array([4e13, 0.0, 0.0])
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(1e14),
                                   jnp.ones(3, bool))
    a = np.asarray(res.alloc)
    assert a[0] == pytest.approx(4e13, rel=1e-5)          # pinned at floor
    assert a[1] == pytest.approx(a[2], rel=1e-5)          # equal √ωΨ shares
    assert a[1] + a[2] == pytest.approx(6e13, rel=1e-5)   # residual capacity


def test_infeasible_floors_scale_down():
    psi = np.array([1e13, 1e13])
    omega = np.ones(2)
    floors = np.array([8e13, 8e13])              # Σ floors = 1.6e14 > 1e14
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(1e14),
                                   jnp.ones(2, bool))
    assert not bool(res.feasible)
    assert float(np.sum(np.asarray(res.alloc))) <= 1e14 * (1 + 1e-5)


def test_generic_active_set_equal_share():
    """active_set_np with unit weights = equal share (Round-Robin baseline)."""
    w = np.ones(4)
    alloc, feas, _ = active_set_np(w, np.zeros(4), 100.0, np.ones(4, bool))
    np.testing.assert_allclose(alloc, 25.0)


@pytest.mark.parametrize("seed", range(40))
@pytest.mark.parametrize("feas", (True, False))
def test_compact_scalar_solver_matches_active_set_np(seed, feas):
    """The simulator's per-node scalar solver (`_active_set_small`, the
    deadline-aware hot path since the compact allocation rewrite) must
    agree with the property-tested vector implementation.  Tolerance is
    ulps: the scalar path sums sequentially, numpy pairwise."""
    from repro.sim.cluster import _active_set_small

    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    w = np.sqrt(np.where(mask, np.maximum(psi, 0.0), 0.0)
                * np.where(mask, np.maximum(omega, 0.0), 0.0))
    ref, _, _ = active_set_np(w, np.where(mask, floors, 0.0), float(cap),
                              mask)
    # the compact path only ever sees the busy (masked-in) instances
    idx = np.nonzero(mask)[0]
    small = _active_set_small([float(w[i]) for i in idx],
                              [float(floors[i]) for i in idx], float(cap))
    # tolerance scales with capacity: the infeasible-floor rescale leaves
    # O(cap * 1e-16) residual dust (capacity minus the rounded floor sum)
    # that the two implementations hand to different entries; a genuinely
    # flipped pin differs by ~the whole allocation and still fails
    np.testing.assert_allclose(np.array(small), ref[idx],
                               rtol=1e-9, atol=float(cap) * 1e-12)
