"""Property tests for the closed-form deadline-aware allocator (Eq. 13–19).

The paper's claim is that the active-set closed form IS the argmin of the
convex problem (16).  We certify:
  * KKT optimality vs a numeric projected-gradient solve,
  * the capacity and floor constraints as invariants under random inputs,
  * exact agreement between the JAX, NumPy, and Pallas implementations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                    # hypothesis is an optional test dep:
    import hypothesis.strategies as st  # without it only the property-based
    from hypothesis import given, settings   # tests below are skipped
except ImportError:
    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

from repro.core import allocator
from repro.core.allocator_np import active_set_np, solve_resource_np
from repro.kernels import ops as kops

S = 12


def _rand_inputs(seed, feasible_floors=True):
    rng = np.random.default_rng(seed)
    psi = np.where(rng.random(S) < 0.8, rng.uniform(0, 1e14, S), 0.0)
    omega = np.where(psi > 0, rng.uniform(0.1, 1e3, S), 0.0)
    cap = rng.uniform(5e13, 3e14)
    floors = np.where(rng.random(S) < 0.4, rng.uniform(0, cap / S, S), 0.0)
    if not feasible_floors:
        floors = floors * 20.0
    mask = rng.random(S) < 0.9
    return psi, omega, floors, cap, mask


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), feas=st.booleans())
def test_capacity_and_floor_invariants(seed, feas):
    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    alloc = np.asarray(res.alloc)
    # capacity: Σ alloc ≤ cap (float32 tolerance)
    assert alloc.sum() <= cap * (1 + 1e-5) + 1e3
    # non-resident instances get nothing
    assert np.all(alloc[~mask] == 0)
    # floors respected whenever they are jointly feasible
    if bool(res.feasible):
        f = np.where(mask, floors, 0.0)
        assert np.all(alloc + cap * 1e-5 + 1e3 >= f)
    # non-negative
    assert np.all(alloc >= 0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_closed_form_matches_numeric_convex_solve(seed):
    """The active-set result attains the numeric optimum of Eq. 16."""
    psi, omega, floors, cap, mask = _rand_inputs(seed, True)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    x_num = allocator.solve_numeric(jnp.asarray(psi), jnp.asarray(omega),
                                    jnp.asarray(floors), jnp.asarray(cap),
                                    jnp.asarray(mask))
    f_closed = float(allocator.objective(res.alloc, jnp.asarray(psi),
                                         jnp.asarray(omega),
                                         jnp.asarray(mask)))
    f_num = float(allocator.objective(x_num, jnp.asarray(psi),
                                      jnp.asarray(omega), jnp.asarray(mask)))
    # closed form must be at least as good as the numeric solve
    assert f_closed <= f_num * (1 + 5e-3) + 1e-9


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), feas=st.booleans())
def test_jax_equals_numpy(seed, feas):
    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(cap),
                                   jnp.asarray(mask))
    a_np, f_np, _ = solve_resource_np(psi, omega, floors, float(cap), mask)
    np.testing.assert_allclose(np.asarray(res.alloc), a_np, rtol=1e-4,
                               atol=cap * 1e-5)
    assert bool(res.feasible) == bool(f_np)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pallas_kernel_equals_oracle(seed):
    rng = np.random.default_rng(seed)
    N = 4
    psi = rng.uniform(0, 1e14, (N, S))
    omega = rng.uniform(0, 100, (N, S))
    cap = rng.uniform(5e13, 2e14, N)
    floors = np.where(rng.random((N, S)) < 0.3,
                      rng.uniform(0, 2e13, (N, S)), 0.0)
    mask = rng.random((N, S)) < 0.9
    al, fe, pin = kops.alloc_active_set(
        jnp.asarray(psi), jnp.asarray(omega), jnp.asarray(floors),
        jnp.asarray(cap), jnp.asarray(mask))
    for n in range(N):
        a_np, f_np, _ = solve_resource_np(psi[n], omega[n], floors[n],
                                          float(cap[n]), mask[n])
        np.testing.assert_allclose(np.asarray(al[n]), a_np, rtol=1e-4,
                                   atol=cap[n] * 1e-5)
        assert bool(fe[n]) == bool(f_np)


def test_sqrt_proportionality():
    """Unfloored instances follow g ∝ √(ωΨ) exactly (Eq. 17)."""
    psi = np.array([1e13, 4e13, 9e13, 0.0])
    omega = np.array([1.0, 1.0, 1.0, 0.0])
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.zeros(4), jnp.asarray(1e14),
                                   jnp.ones(4, bool))
    a = np.asarray(res.alloc)
    w = np.sqrt(psi * omega)
    np.testing.assert_allclose(a[:3] / a[:3].sum(), w[:3] / w[:3].sum(),
                               rtol=1e-5)
    assert a[3] == 0.0
    np.testing.assert_allclose(a.sum(), 1e14, rtol=1e-5)


def test_floor_clipping_redistributes():
    """A pinned instance keeps its floor; the rest re-share (Eq. 18–19)."""
    psi = np.array([1e10, 5e13, 5e13])          # inst 0: tiny work, big floor
    omega = np.ones(3)
    floors = np.array([4e13, 0.0, 0.0])
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(1e14),
                                   jnp.ones(3, bool))
    a = np.asarray(res.alloc)
    assert a[0] == pytest.approx(4e13, rel=1e-5)          # pinned at floor
    assert a[1] == pytest.approx(a[2], rel=1e-5)          # equal √ωΨ shares
    assert a[1] + a[2] == pytest.approx(6e13, rel=1e-5)   # residual capacity


def test_infeasible_floors_scale_down():
    psi = np.array([1e13, 1e13])
    omega = np.ones(2)
    floors = np.array([8e13, 8e13])              # Σ floors = 1.6e14 > 1e14
    res = allocator.solve_resource(jnp.asarray(psi), jnp.asarray(omega),
                                   jnp.asarray(floors), jnp.asarray(1e14),
                                   jnp.ones(2, bool))
    assert not bool(res.feasible)
    assert float(np.sum(np.asarray(res.alloc))) <= 1e14 * (1 + 1e-5)


def test_generic_active_set_equal_share():
    """active_set_np with unit weights = equal share (Round-Robin baseline)."""
    w = np.ones(4)
    alloc, feas, _ = active_set_np(w, np.zeros(4), 100.0, np.ones(4, bool))
    np.testing.assert_allclose(alloc, 25.0)


@pytest.mark.parametrize("seed", range(40))
@pytest.mark.parametrize("feas", (True, False))
def test_compact_scalar_solver_matches_active_set_np(seed, feas):
    """The tiny-gather scalar solver (`_active_set_scalar`, the
    deadline-aware fast path) must agree with the property-tested vector
    implementation — and be BIT-identical to the padded row solver it
    stands in for (same expressions, same tree-ordered reductions)."""
    from repro.sim.cluster import (_active_set_rows, _active_set_scalar,
                                   _pow2_at_least)

    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    w = np.sqrt(np.where(mask, np.maximum(psi, 0.0), 0.0)
                * np.where(mask, np.maximum(omega, 0.0), 0.0))
    ref, _, _ = active_set_np(w, np.where(mask, floors, 0.0), float(cap),
                              mask)
    # the compact path only ever sees the busy (masked-in) instances
    idx = np.nonzero(mask)[0]
    small = _active_set_scalar([float(w[i]) for i in idx],
                               [float(floors[i]) for i in idx], float(cap))
    # tolerance scales with capacity: the infeasible-floor rescale leaves
    # O(cap * 1e-16) residual dust (capacity minus the rounded floor sum)
    # that the two implementations hand to different entries; a genuinely
    # flipped pin differs by ~the whole allocation and still fails
    np.testing.assert_allclose(np.array(small), ref[idx],
                               rtol=1e-9, atol=float(cap) * 1e-12)
    # exact equality with the padded row solver, at two padded widths
    k = len(idx)
    for K in (_pow2_at_least(k), 2 * _pow2_at_least(k)):
        wr = np.zeros((1, K))
        fr = np.zeros((1, K))
        wr[0, :k] = w[idx]
        fr[0, :k] = floors[idx]
        rows = _active_set_rows(wr, fr, np.array([float(cap)]))
        np.testing.assert_array_equal(np.array(small), rows[0, :k])


@pytest.mark.parametrize("seed", range(30))
@pytest.mark.parametrize("feas", (True, False))
def test_row_solver_matches_active_set_np(seed, feas):
    """`_active_set_rows` (the padded multi-problem engine solver) must
    agree with the property-tested vector implementation row by row,
    regardless of how much zero padding the batching added."""
    from repro.sim.cluster import _active_set_rows, _pow2_at_least

    psi, omega, floors, cap, mask = _rand_inputs(seed, feas)
    w = np.sqrt(np.where(mask, np.maximum(psi, 0.0), 0.0)
                * np.where(mask, np.maximum(omega, 0.0), 0.0))
    ref, _, _ = active_set_np(w, np.where(mask, floors, 0.0), float(cap),
                              mask)
    idx = np.nonzero(mask)[0]
    k = len(idx)
    for K in (_pow2_at_least(k), 2 * _pow2_at_least(k)):   # pad-invariance
        wr = np.zeros((1, K))
        fr = np.zeros((1, K))
        wr[0, :k] = w[idx]
        fr[0, :k] = floors[idx]
        rows = _active_set_rows(wr, fr, np.array([float(cap)]))
        np.testing.assert_allclose(rows[0, :k], ref[idx],
                                   rtol=1e-9, atol=float(cap) * 1e-12)


@pytest.mark.parametrize("policy", ("equal-share", "maxweight", "market"))
def test_compact_baselines_match_full_width_reference(policy):
    """The compact busy-instances-per-node baselines must reproduce the
    historical full-[N, S] `allocator_inputs` + `active_set_np` path
    (ulp-level: tree sums vs pairwise sums)."""
    from repro.core.baselines import (EqualShareAllocation,
                                     MarketAllocation, MaxWeightAllocation)
    from repro.sim import make_scenario, workload_for
    from repro.sim.cluster import ClusterState, Job

    sc = make_scenario("paper", n_ai_requests=60)
    reqs, _ = workload_for(sc, seed=3)
    cluster = ClusterState(sc["nodes"], sc["instances"], sc["placement"],
                           sc["transport_delay"])
    # enqueue a mixed backlog across DU / CU-UP / AI instances
    for i, r in enumerate(reqs[:40]):
        if r.cls.value == "RAN":
            sid = cluster.du_of(r.cell)
            cluster.push_job(sid, Job(req=r, rem_g=max(r.du_work_g, 1.0),
                                      rem_c=0.0,
                                      abs_deadline=r.arrival + r.deadline))
        else:
            sid = sc["service_sids"][r.service][i % 2]
            cluster.push_job(sid, Job(req=r, rem_g=max(r.ai_work_g, 1.0),
                                      rem_c=max(r.ai_work_c, 0.0),
                                      abs_deadline=r.arrival + r.deadline))
    t = 0.05
    alloc_cls = {"equal-share": EqualShareAllocation,
                 "maxweight": MaxWeightAllocation,
                 "market": MarketAllocation}[policy]

    # full-width reference: the pre-compact implementation
    psi_g, psi_c, omega, fg, fc, mask = cluster.allocator_inputs(t)
    N, S = psi_g.shape
    g_ref = np.zeros((N, S))
    c_ref = np.zeros((N, S))

    def full_weights(psi_row, other_row, omega_row):
        if policy == "equal-share":
            return (psi_row > 0).astype(float)
        if policy == "market":
            return omega_row * psi_row
        out = np.zeros_like(psi_row)                       # maxweight
        w = omega_row * psi_row
        if np.any(w > 0):
            out[int(np.argmax(w))] = 1.0
        return out

    for n in range(N):
        wg = full_weights(psi_g[n], psi_c[n], omega[n])
        wc = full_weights(psi_c[n], psi_g[n], omega[n])
        g_ref[n], _, _ = active_set_np(wg, fg[n],
                                       float(cluster.gpu_capacity[n]),
                                       mask[n])
        c_ref[n], _, _ = active_set_np(wc, fc[n],
                                       float(cluster.cpu_capacity[n]),
                                       mask[n])
    g_ref = g_ref[cluster.placement, np.arange(S)]
    c_ref = c_ref[cluster.placement, np.arange(S)]

    alloc_cls().allocate(cluster, t)
    cap = float(cluster.gpu_capacity.max())
    np.testing.assert_allclose(cluster.alloc_g, g_ref, rtol=1e-9,
                               atol=cap * 1e-12)
    np.testing.assert_allclose(cluster.alloc_c, c_ref, rtol=1e-9,
                               atol=float(cluster.cpu_capacity.max()) * 1e-9)
