"""Fleet-evaluation harness: job expansion, parallel sweeps, reports."""
import dataclasses
import json

import pytest

from repro.eval import (SweepSpec, aggregate, build_report, expand_jobs,
                        format_table, make_method, method_names, run_job,
                        run_sweep, write_report)

MINI = SweepSpec(
    methods=("haf-static", "round-robin"),
    scenarios=("paper", {"family": "skewed-hetero",
                         "params": {"n_nodes": 4}}),
    seeds=(0, 1),
    n_ai_requests=120,
    workers=1,
)


@pytest.fixture(scope="module")
def mini_rows():
    return run_sweep(MINI)


def test_method_registry_covers_table3():
    assert {"haf", "haf-static", "round-robin", "lyapunov", "game-theory",
            "caora", "haf-llm"} <= set(method_names())
    for name in method_names():
        kw = {"cmd": "cat"} if name == "haf-llm" else {}
        placement, allocation, rr = make_method(name, **kw)
        assert hasattr(placement, "decide")
        assert hasattr(allocation, "allocate")
        assert isinstance(rr, bool)


def test_expand_jobs_is_full_product():
    jobs = expand_jobs(MINI)
    assert len(jobs) == 2 * 2 * 2
    keys = {(j["method"], j["scenario_label"], j["seed"]) for j in jobs}
    assert len(keys) == 8


def test_mini_sweep_rows_well_formed(mini_rows):
    assert len(mini_rows) == 8
    for row in mini_rows:
        for k in ("overall", "ran", "ai", "large_ai", "small_ai",
                  "mig_large", "mig_total", "method", "scenario", "seed",
                  "wall_s", "n_requests"):
            assert k in row, k
        assert 0.0 <= row["overall"] <= 1.0


def test_run_job_deterministic():
    job = expand_jobs(MINI)[0]
    a, b = run_job(dict(job)), run_job(dict(job))
    for k in ("overall", "ran", "ai", "mig_total", "n_events"):
        assert a[k] == b[k], k


def test_aggregate_mean_ci(mini_rows):
    cells = aggregate(mini_rows)
    assert len(cells) == 4                   # 2 methods x 2 scenarios
    for cell in cells:
        assert cell["seeds"] == [0, 1]
        for m in ("overall", "ran", "large_ai", "small_ai"):
            assert cell[m]["n"] == 2
            assert cell[m]["ci95"] >= 0.0
            assert 0.0 <= cell[m]["mean"] <= 1.0
        assert cell["mig_total"]["mean"] >= 0.0
    # hand-check one mean
    cell = next(c for c in cells if c["method"] == "haf-static"
                and c["scenario"] == "paper")
    manual = [r["overall"] for r in mini_rows
              if r["method"] == "haf-static" and r["scenario"] == "paper"]
    assert cell["overall"]["mean"] == pytest.approx(sum(manual) / 2)


def test_report_roundtrips_as_json(tmp_path, mini_rows):
    report = build_report(MINI, mini_rows)
    path = write_report(report, tmp_path / "report.json")
    loaded = json.loads(path.read_text())
    assert loaded["kind"] == "repro.eval.sweep_report"
    assert loaded["n_runs"] == 8
    assert len(loaded["aggregate"]) == 4
    assert loaded["spec"]["seeds"] == [0, 1]
    table = format_table(loaded["aggregate"])
    assert "haf-static" in table and "skewed-hetero" in table


def test_parallel_equals_serial():
    spec = SweepSpec(methods=("haf-static",),
                     scenarios=("paper", "flash-crowd"),
                     seeds=(0,), n_ai_requests=100, workers=2)
    serial = run_sweep(dataclasses.replace(spec, workers=1))
    parallel = run_sweep(spec)
    key = lambda r: (r["method"], r["scenario"], r["seed"])  # noqa: E731
    for s, p in zip(sorted(serial, key=key), sorted(parallel, key=key)):
        assert s["overall"] == p["overall"]
        assert s["n_events"] == p["n_events"]


def test_unknown_method_raises():
    with pytest.raises(KeyError, match="unknown method"):
        make_method("definitely-not-a-method")


def test_batched_sweep_equals_serial(mini_rows):
    """batch_seeds groups (scenario, method) cells into run_batch calls;
    the per-row results must equal the classic per-job path."""
    batched = run_sweep(dataclasses.replace(MINI, batch_seeds=2))
    key = lambda r: (r["method"], r["scenario"], r["seed"])  # noqa: E731
    for s, b in zip(sorted(mini_rows, key=key), sorted(batched, key=key)):
        assert s["overall"] == b["overall"]
        assert s["n_events"] == b["n_events"]
        assert s["mig_total"] == b["mig_total"]
        assert b["batch"] == 2


def test_batched_sweep_partial_batches():
    """Seed counts that don't divide batch_seeds still cover every job."""
    spec = SweepSpec(methods=("haf-static",), scenarios=("paper",),
                     seeds=(0, 1, 2), n_ai_requests=100, batch_seeds=2)
    rows = run_sweep(spec)
    assert sorted(r["seed"] for r in rows) == [0, 1, 2]
    assert sorted(r["batch"] for r in rows) == [1, 2, 2]


def test_attach_scenarios_builds_each_cell_once(monkeypatch):
    """The classic path serializes one scenario per group instead of
    re-running make_scenario in every job."""
    import repro.eval.sweep as sweep_mod

    spec = SweepSpec(methods=("haf-static", "round-robin"),
                     scenarios=("paper",), seeds=(0, 1),
                     n_ai_requests=100)
    jobs = sweep_mod.expand_jobs(spec)
    calls = []
    real = sweep_mod.scenario_for_job

    def counting(job):
        calls.append(job["family"])
        return real(job)

    monkeypatch.setattr(sweep_mod, "scenario_for_job", counting)
    sweep_mod.attach_scenarios(jobs)
    assert len(calls) == 1                      # 4 jobs, 1 scenario build
    assert all("scenario" in j for j in jobs)
    # run_job must reuse the attached dict, not rebuild
    row = sweep_mod.run_job(jobs[0])
    assert len(calls) == 1
    assert 0.0 <= row["overall"] <= 1.0
