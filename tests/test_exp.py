"""Declarative experiment layer: grammar, specs, artifacts, resume."""
import dataclasses
import json
import pathlib
import subprocess
import sys

import jax
import pytest

import repro.eval.sweep as sweep_mod
from repro.core.critic import Critic, init_params
from repro.eval import cli
from repro.exp import (ArtifactError, ExperimentSpec, FingerprintMismatch,
                       GrammarError, SpecError, format_method,
                       format_scenario, format_value, parse_method,
                       parse_methods, parse_scenario, parse_seeds,
                       parse_value, resolve_artifact, run_experiment,
                       save_critic)
from repro.exp.provenance import completed_rows

MOCK_LLM = pathlib.Path(__file__).resolve().parent / "mock_llm.py"


# --------------------------------------------------------------------------- #
# grammar
# --------------------------------------------------------------------------- #
def test_value_round_trip():
    for v in (3, -1, 0.75, 1.0, 2.5e-3, True, False, None, "qwen3-32b-sim",
              "@critic?", "a b, (c)=d", 'quo"te', "back\\slash", "0.75",
              "none", "rho=0.75", ""):
        assert parse_value(format_value(v)) == v, v


def test_parse_method_forms():
    assert parse_method("haf-static") == \
        {"name": "haf-static", "params": {}, "label": "haf-static"}
    m = parse_method("haf(agent=qwen3-32b-sim, critic=@critic, K=3)")
    assert m["name"] == "haf"
    assert m["params"] == {"agent": "qwen3-32b-sim",
                           "critic_path": "@critic", "K": 3}
    m = parse_method('caora(alpha=0.4, label=CAORA)')
    assert m == {"name": "caora", "params": {"alpha": 0.4}, "label": "CAORA"}


def test_haf_llm_cmd_may_contain_commas():
    cmd = 'curl -s localhost:8000 -d {"a": 1, "b": [2, 3]} | jq .text'
    m = parse_method(f'haf-llm(cmd="{cmd.replace(chr(92), "")}")')
    assert m["params"]["cmd"] == cmd.replace(chr(92), "")
    assert parse_method(format_method(m)) == m


def test_legacy_haf_llm_sugar_still_parses():
    m = parse_method("haf-llm:curl -s localhost")
    assert m["name"] == "haf-llm"
    assert m["params"] == {"cmd": "curl -s localhost"}
    assert m["label"] == "haf-llm(curl -s localhost)"


def test_legacy_haf_llm_with_comma_errors_at_parse():
    # the legacy sugar next to a comma is ambiguous (command comma vs
    # method separator; the old parser silently truncated the command) —
    # it must error with a pointer at the grammar form, even when the
    # post-comma fragment happens to be a valid method name
    for text in ("haf-llm:curl -s x --data a, b",
                 "haf-llm:python serve.py --modes a,haf",
                 "haf-static,haf-llm:curl -s x"):
        with pytest.raises(GrammarError, match=r'haf-llm\(cmd='):
            parse_methods(text)
    # alone (no commas) the legacy sugar still works…
    assert parse_methods("haf-llm:curl -s x")[0]["params"]["cmd"] \
        == "curl -s x"
    # …and a spec-file list entry is never comma-split, so a legacy entry
    # there keeps its full command
    spec = ExperimentSpec(methods=("haf-llm:curl -s x --data a,b",),
                          scenarios=("paper",))
    assert spec.methods[0]["params"]["cmd"] == "curl -s x --data a,b"


def test_method_grammar_round_trip():
    for text in ("haf-static",
                 "haf(K=5, agent=qwen2.5-72b-sim, critic_path=@critic?)",
                 'haf-llm(cmd="vllm serve m, n --port 80", timeout=9.5)',
                 "caora(alpha=0.25, label=CAORA)",
                 "lyapunov(V=0.5)"):
        m = parse_method(text)
        assert parse_method(format_method(m)) == m, text


def test_scenario_grammar_round_trip():
    for text in ("paper",
                 "flash-crowd(magnitude=6.0, n_spikes=2, rho=0.95)",
                 'paper(n_ai_requests=3750, rho=0.75, label="rho=0.75")'):
        s = parse_scenario(text)
        assert parse_scenario(format_scenario(s)) == s, text


def test_parse_seeds_forms():
    assert parse_seeds("3") == [0, 1, 2]
    assert parse_seeds("0,2,5") == [0, 2, 5]
    assert parse_seeds("0..4") == [0, 1, 2, 3, 4]
    assert parse_seeds("0,") == [0]
    assert parse_seeds("0..1,7") == [0, 1, 7]


def test_parse_seeds_zero_points_at_spec_form():
    with pytest.raises(GrammarError, match="seeds = \\[0\\]"):
        parse_seeds("0")
    with pytest.raises(GrammarError):
        parse_seeds("-2")
    with pytest.raises(GrammarError):
        parse_seeds("1..x")


# --------------------------------------------------------------------------- #
# ExperimentSpec
# --------------------------------------------------------------------------- #
MINI_KW = dict(methods=("haf-static", "round-robin"),
               scenarios=("paper", "skewed-hetero(n_nodes=4)"),
               seeds=(0, 1), n_ai_requests=120)


def test_spec_file_round_trip(tmp_path):
    spec = ExperimentSpec(name="mini", workers=2, **MINI_KW)
    for suffix in (".toml", ".json"):
        path = spec.to_file(tmp_path / f"mini{suffix}")
        back = ExperimentSpec.from_file(path)
        assert back.spec_hash() == spec.spec_hash(), suffix
        assert back.expand() == spec.expand(), suffix


def test_spec_grammar_equals_raw_dicts():
    by_grammar = ExperimentSpec(
        methods=("haf(agent=qwen3-32b-sim, critic=@c?)",
                 "caora(alpha=0.3)"),
        scenarios=("flash-crowd(rho=0.95, n_ai_requests=400)",))
    by_dicts = ExperimentSpec(
        methods=({"name": "haf",
                  "params": {"agent": "qwen3-32b-sim",
                             "critic_path": "@c?"}, "label": "haf"},
                 {"name": "caora", "params": {"alpha": 0.3},
                  "label": "caora"}),
        scenarios=({"family": "flash-crowd",
                    "params": {"rho": 0.95, "n_ai_requests": 400},
                    "label": "flash-crowd"},))
    assert by_grammar.expand() == by_dicts.expand()
    assert by_grammar.identity_hash() == by_dicts.identity_hash()


def test_spec_expand_matches_sweep(tmp_path):
    from repro.eval import expand_jobs
    spec = ExperimentSpec(**MINI_KW)
    assert spec.expand() == expand_jobs(spec.to_sweep_spec())
    assert len(spec.expand()) == 2 * 2 * 2


def test_identity_hash_scope():
    spec = ExperimentSpec(**MINI_KW)
    # non-result-affecting knobs keep the identity (resume survives them)
    assert spec.replace(workers=8, engine="scalar", batch=4, seeds=(0,),
                        name="x", out="y.json").identity_hash() \
        == spec.identity_hash()
    # result-affecting knobs change it
    assert spec.replace(n_ai_requests=121).identity_hash() \
        != spec.identity_hash()
    assert spec.with_scenario_params("paper", rho=0.8).identity_hash() \
        != spec.identity_hash()


def test_with_params_selectors():
    spec = ExperimentSpec(
        methods=("caora(alpha=0.5, label=CAORA)", "haf-static"),
        scenarios=("paper",))
    out = spec.with_method_params("CAORA", alpha=0.125)
    assert out.methods[0]["params"]["alpha"] == 0.125
    with pytest.raises(SpecError, match="no method matches"):
        spec.with_method_params("nope", alpha=1.0)


def test_validate_catches_everything():
    cases = [
        (dict(methods=("definitely-not-a-method",)), "unknown method"),
        (dict(scenarios=("not-a-family",)), "unknown scenario family"),
        (dict(scenarios=("flash-crowd(magnitud=6)",)), "unknown parameter"),
        (dict(methods=("haf(agnt=x)",)), "unknown parameter"),
        (dict(methods=("haf-llm",)), "needs cmd="),
        (dict(engine="pallas"), "batch > 1"),
        (dict(seeds=()), "no seeds"),
        # duplicate labels would merge aggregation cells and cross-resume
        (dict(scenarios=("paper(rho=0.75)", "paper(rho=1.25)")),
         "duplicate scenario labels"),
        (dict(methods=("haf(K=3)", "haf(K=5)")), "duplicate method labels"),
    ]
    for kw, match in cases:
        spec = ExperimentSpec(**{**dict(methods=("haf-static",),
                                        scenarios=("paper",)), **kw})
        with pytest.raises(SpecError, match=match):
            spec.validate()


def test_spec_file_unknown_key(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"methods": ["haf-static"], "typo_key": 1}))
    with pytest.raises(SpecError, match="typo_key"):
        ExperimentSpec.from_file(path)


# --------------------------------------------------------------------------- #
# artifact store
# --------------------------------------------------------------------------- #
def _tiny_critic(seed: int = 0) -> Critic:
    return Critic(params=init_params(jax.random.PRNGKey(seed), hidden=8))


def test_artifact_refs(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path))
    critic = _tiny_critic()
    save_critic(critic, tmp_path / "critic.json", families=("paper",),
                data_hash="d" * 64)
    path, fp = resolve_artifact("@critic")
    assert pathlib.Path(path) == tmp_path / "critic.json"
    assert fp == critic.fingerprint()
    # optional refs: absent -> (None, None), never an error
    assert resolve_artifact("@nope?") == (None, None)
    with pytest.raises(ArtifactError, match="@nope"):
        resolve_artifact("@nope")
    # fingerprint pins
    pin = f"critic@{critic.fingerprint()[:10]}"
    assert resolve_artifact(pin) == (path, critic.fingerprint())
    with pytest.raises(ArtifactError, match="no artifact"):
        resolve_artifact("critic@" + "0" * 12)
    # plain paths resolve to themselves and pick up the sidecar manifest
    ppath, pfp = resolve_artifact(str(tmp_path / "critic.json"))
    assert (ppath, pfp) == (str(tmp_path / "critic.json"),
                            critic.fingerprint())


def test_load_critic_verifies_fingerprint(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path))
    from repro.eval.policies import _load_critic
    critic = _tiny_critic()
    save_critic(critic, tmp_path / "critic.json", families=("paper",))
    loaded = _load_critic("@critic")
    assert loaded.fingerprint() == critic.fingerprint()
    # artifact changes under a stale manifest -> load must raise
    _tiny_critic(seed=1).save(str(tmp_path / "critic.json"))
    with pytest.raises(FingerprintMismatch):
        _load_critic("@critic")
    # a plain path with no manifest stays unverified (legacy behavior)
    _tiny_critic(seed=2).save(str(tmp_path / "bare.json"))
    assert _load_critic(str(tmp_path / "bare.json")) is not None
    # optional ref without artifact -> agent-only (None)
    assert _load_critic("@absent?") is None


# --------------------------------------------------------------------------- #
# provenance + resume
# --------------------------------------------------------------------------- #
@pytest.fixture()
def small_spec(tmp_path):
    return ExperimentSpec(methods=("haf-static",), scenarios=("paper",),
                          seeds=(0, 1), n_ai_requests=100,
                          out=str(tmp_path / "report.json"))


def _row_key(r):
    return (r["method"], r["scenario"], r["seed"])


def test_report_embeds_provenance(small_spec):
    report = run_experiment(small_spec, resume=False)
    prov = report["provenance"]
    assert prov["spec_hash"] == small_spec.spec_hash()
    assert prov["identity_hash"] == small_spec.identity_hash()
    assert prov["spec"]["methods"][0]["name"] == "haf-static"
    assert len(prov["scenario_fingerprints"]["paper"]) == 64
    assert prov["backend"]["engine"] == "numpy"
    # report round-trips as strict JSON with provenance intact
    loaded = json.loads(pathlib.Path(small_spec.out).read_text())
    assert loaded["provenance"]["spec_hash"] == small_spec.spec_hash()


def test_resume_skips_completed_rows(small_spec, monkeypatch):
    ran = []
    real = sweep_mod.run_sweep

    def counting(spec, verbose=False, jobs=None):
        ran.append(0 if jobs is None else len(jobs))
        return real(spec, verbose=verbose, jobs=jobs)

    monkeypatch.setattr(sweep_mod, "run_sweep", counting)
    r1 = run_experiment(small_spec)
    assert ran == [2] and r1["provenance"]["resumed_rows"] == 0

    # identical rerun: everything resumes, nothing runs
    r2 = run_experiment(small_spec)
    assert ran == [2] and r2["provenance"]["resumed_rows"] == 2
    assert sorted(map(_row_key, r2["runs"])) \
        == sorted(map(_row_key, r1["runs"]))

    # partial report: drop one row -> exactly one job recomputes
    path = pathlib.Path(small_spec.out)
    report = json.loads(path.read_text())
    report["runs"] = report["runs"][:1]
    path.write_text(json.dumps(report))
    r3 = run_experiment(small_spec)
    assert ran == [2, 1] and r3["provenance"]["resumed_rows"] == 1
    for a, b in zip(sorted(r1["runs"], key=_row_key),
                    sorted(r3["runs"], key=_row_key)):
        assert a["overall"] == b["overall"]
        assert a["n_events"] == b["n_events"]

    # resume=False recomputes everything
    r4 = run_experiment(small_spec, resume=False)
    assert ran == [2, 1, 2] and r4["provenance"]["resumed_rows"] == 0

    # a result-affecting change invalidates the prior rows
    r5 = run_experiment(small_spec.replace(n_ai_requests=101,
                                           out=small_spec.out))
    assert ran == [2, 1, 2, 2] and r5["provenance"]["resumed_rows"] == 0


def test_resume_key_rejects_foreign_reports(small_spec):
    run_experiment(small_spec)
    report = json.loads(pathlib.Path(small_spec.out).read_text())
    assert len(completed_rows(report, report["provenance"]["resume_key"])) \
        == 2
    assert completed_rows(report, "not-the-key") == {}
    # truncated rows are never resumed (they must recompute)
    report["runs"][0]["truncated"] = True
    assert len(completed_rows(report, report["provenance"]["resume_key"])) \
        == 1


def test_resume_invalidated_by_artifact_retrain(tmp_path, monkeypatch):
    """Same spec text, retrained critic -> the resume key must change."""
    from repro.exp.provenance import artifact_provenance, resume_key
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path))
    spec = ExperimentSpec(methods=("haf(critic=@critic)",),
                          scenarios=("paper",), seeds=(0,))
    save_critic(_tiny_critic(seed=0), tmp_path / "critic.json")
    key0 = resume_key(spec, artifact_provenance(spec))
    save_critic(_tiny_critic(seed=1), tmp_path / "critic.json")
    key1 = resume_key(spec, artifact_provenance(spec))
    assert key0 != key1
    assert spec.spec_hash() == spec.spec_hash()   # spec text unchanged


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #
def test_cli_spec_file_equals_raw_flags(tmp_path):
    methods = ("haf(agent=qwen3-32b-sim, critic=@critic?, label=HAF)",
               "haf-static(label=HAF-Static)")
    scenarios = ("paper(n_ai_requests=400, rho=1.0)",)
    spec = ExperimentSpec(methods=methods, scenarios=scenarios, seeds=(0,),
                          name="parity")
    path = spec.to_file(tmp_path / "parity.toml")

    ap = cli._build_parser()
    from_file = cli.build_experiment(ap.parse_args(["--spec", str(path)]))
    from_flags = cli.build_experiment(ap.parse_args(
        ["--methods", ",".join(methods),
         "--scenarios", ",".join(scenarios),
         "--seeds", "0,"]))
    assert from_file.expand() == from_flags.expand()
    assert from_file.identity_hash() == from_flags.identity_hash()


def test_cli_flags_override_spec_file(tmp_path):
    spec = ExperimentSpec(methods=("haf-static",), scenarios=("paper",),
                          seeds=(0,), workers=4)
    path = spec.to_file(tmp_path / "base.toml")
    ap = cli._build_parser()
    built = cli.build_experiment(ap.parse_args(
        ["--spec", str(path), "--seeds", "0..2", "--engine", "scalar",
         "--requests", "99", "--workers", "1"]))
    assert built.seeds == (0, 1, 2)
    assert built.engine == "scalar"
    assert built.n_ai_requests == 99
    assert built.workers == 1
    assert built.methods == spec.methods          # untouched by overrides


def test_cli_validate_runs_nothing(tmp_path, capsys):
    out = tmp_path / "never_written.json"
    rc = cli.main(["--validate", "--methods", "haf-static,round-robin",
                   "--scenarios", "paper", "--seeds", "2",
                   "--out", str(out)])
    assert rc == 0
    assert not out.exists()
    text = capsys.readouterr().out
    assert "validate only" in text and "nothing run" in text
    assert text.count("pending") == 4             # 2 methods x 2 seeds


def test_cli_seeds_zero_error_mentions_spec_grammar(capsys):
    with pytest.raises(SystemExit):
        cli.main(["--seeds", "0", "--methods", "haf-static",
                  "--scenarios", "paper"])
    err = capsys.readouterr().err
    assert "seed COUNT" in err and "spec file" in err


def test_cli_legacy_haf_llm_comma_error(capsys):
    with pytest.raises(SystemExit):
        cli.main(["--validate", "--scenarios", "paper",
                  "--methods", "haf-llm:curl -s x --data a, b"])
    err = capsys.readouterr().err
    assert 'haf-llm(cmd=' in err


# --------------------------------------------------------------------------- #
# mock LLM end-to-end (the haf-llm path with zero network)
# --------------------------------------------------------------------------- #
def test_mock_llm_script_contract():
    prompt = "\n".join([
        "Answer with a JSON array of at most 2 candidate identifiers.",
        'Example: ["mig:s12:n0->n1", "no-migration"]',
        "",
        "CANDIDATE ACTIONS (choose identifiers from this list only):",
        "  no-migration : keep the current placement",
        "  mig:s3:n0->n1 : move large0 n0->n1",
        "  mig:s1:n2->n0 : move small0 n2->n0",
    ])
    out = subprocess.run([sys.executable, str(MOCK_LLM)], input=prompt,
                         capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    # deterministic: first K-1 ids lexicographically + the hedge; the
    # example id from the preamble must NOT leak in
    assert json.loads(out.stdout) == ["mig:s1:n2->n0", "no-migration"]


def test_mock_llm_sweep_end_to_end():
    """haf-llm(cmd=...) drives a real sweep offline, reproducibly."""
    cmd = f"{sys.executable} {MOCK_LLM}"
    spec = ExperimentSpec(
        methods=(f'haf-llm(cmd="{cmd}", label=HAF-MockLLM)',),
        scenarios=("paper",), seeds=(0,), n_ai_requests=100)
    a = run_experiment(spec, resume=False)
    b = run_experiment(spec, resume=False)
    row_a, row_b = a["runs"][0], b["runs"][0]
    assert row_a["method"] == "HAF-MockLLM"
    assert 0.0 <= row_a["overall"] <= 1.0
    assert row_a["n_requests"] >= 100      # AI requests + the RAN stream
    for key in ("overall", "ran", "ai", "mig_total", "n_events"):
        assert row_a[key] == row_b[key], key
