"""Simulator invariants: request accounting, RAN floors, migrations, VRAM."""
import numpy as np
import pytest

from repro.core.baselines import EqualShareAllocation
from repro.sim import (Simulator, WorkloadConfig, generate_workload,
                       paper_scenario)
from repro.sim.engine import DeadlineAwareAllocation, StaticPlacement
from repro.sim.types import InstanceCategory, MigrationAction, RequestClass
from repro.core.controller import ScriptedPlacement


@pytest.fixture(scope="module")
def scenario():
    return paper_scenario()


@pytest.fixture(scope="module")
def small_run(scenario):
    wcfg = WorkloadConfig(rho=1.0, n_ai_requests=600, seed=3)
    reqs, _ = generate_workload(wcfg, scenario["work_models"])
    sim = Simulator(scenario, epoch_interval=5.0)
    res = sim.run(reqs, StaticPlacement(), DeadlineAwareAllocation())
    return reqs, res


def test_all_requests_terminate(small_run):
    reqs, res = small_run
    unfinished = [r for r in res.requests
                  if r.finish < 0 and r.rid not in res.dropped]
    assert not unfinished, f"{len(unfinished)} requests never completed"


def test_fulfillment_consistency(small_run):
    _, res = small_run
    f = res.fulfillment()
    assert 0.0 <= f["overall"] <= 1.0
    # overall is the request-weighted blend of the class rates
    per = [int(r.fulfilled() and r.rid not in res.dropped)
           for r in res.requests]
    assert abs(f["overall"] - np.mean(per)) < 1e-9


def test_ran_floors_protect_under_ai_overload(small_run):
    """Eq. 5b via floors: RAN stays ≥90% even at ρ=1.0 AI saturation."""
    _, res = small_run
    assert res.fulfillment()["RAN"] >= 0.90


def test_latency_includes_transport(scenario, small_run):
    reqs, res = small_run
    done = [r for r in res.requests
            if r.cls != RequestClass.RAN and r.finish > 0]
    assert done
    # AI latency ≥ RAN-packet processing delay (δ_q component)
    assert min(r.finish - r.arrival for r in done) >= \
        scenario["ran_packet_delay"] * 0.999


def test_scripted_migration_applies_reconfig(scenario):
    wcfg = WorkloadConfig(rho=1.0, n_ai_requests=400, seed=4)
    reqs, _ = generate_workload(wcfg, scenario["work_models"])
    sim = Simulator(scenario, epoch_interval=5.0)
    res = sim.run(reqs, ScriptedPlacement({1: ("large0", 1)}),
                  DeadlineAwareAllocation())
    assert len(res.migrations) == 1
    t, a = res.migrations[0]
    inst = scenario["instances"][a.sid]
    assert inst.name == "large0" and a.dst == 1
    assert inst.reconfig_s == pytest.approx(8.0)   # Table I large-AI reload


def test_migration_respects_vram(scenario):
    """large-AI (28 GB) can never land on a 24 GB cpu-heavy node (Eq. 4)."""
    from repro.sim.cluster import ClusterState
    cl = ClusterState(scenario["nodes"], scenario["instances"],
                      scenario["placement"], scenario["transport_delay"])
    large_sid = next(s.sid for s in scenario["instances"]
                     if s.name == "large0")
    bad = MigrationAction(sid=large_sid, src=0, dst=2)   # n2 = cpu-heavy
    assert not cl.migration_feasible(bad)
    ok = MigrationAction(sid=large_sid, src=0, dst=1)
    assert cl.migration_feasible(ok)


def test_capacity_never_exceeded(scenario):
    """Σ allocations ≤ node capacity at every epoch (Eq. 3)."""
    wcfg = WorkloadConfig(rho=1.25, n_ai_requests=400, seed=5)
    reqs, _ = generate_workload(wcfg, scenario["work_models"])
    sim = Simulator(scenario, epoch_interval=5.0)
    violations = []

    def hook(rec, cluster):
        g = np.zeros(cluster.N)
        c = np.zeros(cluster.N)
        for sid in range(cluster.S):
            n = cluster.placement[sid]
            g[n] += cluster.alloc_g[sid]
            c[n] += cluster.alloc_c[sid]
        if np.any(g > cluster.gpu_capacity * (1 + 1e-6)):
            violations.append(("gpu", rec.epoch))
        if np.any(c > cluster.cpu_capacity * (1 + 1e-6)):
            violations.append(("cpu", rec.epoch))

    sim.run(reqs, StaticPlacement(), DeadlineAwareAllocation(),
            epoch_hook=hook)
    assert not violations


def test_equal_share_also_respects_floors(scenario):
    wcfg = WorkloadConfig(rho=1.0, n_ai_requests=400, seed=6)
    reqs, _ = generate_workload(wcfg, scenario["work_models"])
    sim = Simulator(scenario, epoch_interval=5.0)
    res = sim.run(reqs, StaticPlacement(), EqualShareAllocation())
    assert res.fulfillment()["RAN"] >= 0.90


def test_rr_dispatch_changes_routing(scenario):
    wcfg = WorkloadConfig(rho=0.75, n_ai_requests=300, seed=7)
    reqs, _ = generate_workload(wcfg, scenario["work_models"])
    sim = Simulator(scenario, epoch_interval=5.0)
    r1 = sim.run(reqs, StaticPlacement(), DeadlineAwareAllocation(),
                 rr_dispatch=False)
    r2 = sim.run(reqs, StaticPlacement(), DeadlineAwareAllocation(),
                 rr_dispatch=True)
    t1 = [r.target_sid for r in r1.requests if r.cls.is_ai]
    t2 = [r.target_sid for r in r2.requests if r.cls.is_ai]
    assert t1 != t2


def test_workload_rho_scaling(scenario):
    w1, i1 = generate_workload(WorkloadConfig(rho=0.75, n_ai_requests=500,
                                              seed=0),
                               scenario["work_models"])
    w2, i2 = generate_workload(WorkloadConfig(rho=1.25, n_ai_requests=500,
                                              seed=0),
                               scenario["work_models"])
    assert i2["lambda_ai"] > i1["lambda_ai"] * 1.5
    # both classes scale together (paper: same factor at each load point)
    assert i2["lambda_ran"] / i1["lambda_ran"] == pytest.approx(
        i2["lambda_ai"] / i1["lambda_ai"], rel=0.05)
