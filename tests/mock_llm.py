"""Deterministic offline stand-in for an external LLM placement endpoint.

Reads the structured placement prompt (:mod:`repro.core.prompts`) on
stdin and writes an ordered JSON shortlist to stdout — the exact contract
``haf-llm(cmd="...")`` methods and ``python -m repro.launch.serve
--llm-cmd`` expect from a served model, with zero network and zero
randomness: the shortlist is the first K−1 candidate identifiers from the
CANDIDATE ACTIONS list in lexicographic order, hedged with
``no-migration`` (mirroring how the real agents always keep the
no-migration option).

Usage in a sweep (commas in the command are fine — the grammar quotes
them):

    python -m repro.eval --methods \
        'haf-llm(cmd="python tests/mock_llm.py")' --scenarios paper

The same prompt always yields the same shortlist, so sweeps through this
endpoint are reproducible run-to-run — which is what the end-to-end
``haf-llm`` tests pin.

Chaos flags turn the stand-in into a deterministic *flaky* endpoint for
fault-injection tests (the draw is a pure hash of ``--seed`` and the
prompt text — the same scheme as :func:`repro.faults.script.fault_draw` —
so a given prompt either always fails or always succeeds for a seed):

    --fail-rate P   fraction of prompts that fail (default 0.0)
    --garbage       failures print an unparseable refusal (exit 0,
                    malformed) instead of crashing
    --hang-s S      failures sleep S seconds before answering (the
                    client's timeout decides whether that is a fault)
    --seed N        reseeds which prompts fail (default 0)

Without ``--garbage``/``--hang-s``, a drawn failure writes a diagnostic
to stderr and exits 17 — the crash mode ``make_llm_complete`` maps to
:class:`repro.faults.errors.LLMCrashError`.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import re
import sys
import time

CANDIDATE_RE = re.compile(r"mig:s\d+:n\d+->n\d+")
K_RE = re.compile(r"at most (\d+) candidate")


def shortlist(prompt: str) -> list:
    # parse only the candidate section: identifiers quoted in the policy
    # preamble (the example answer) must not leak into the shortlist
    _, _, candidates = prompt.rpartition("CANDIDATE ACTIONS")
    ids = sorted(set(CANDIDATE_RE.findall(candidates)))
    m = K_RE.search(prompt)
    k = int(m.group(1)) if m else 3
    return ids[:max(k - 1, 0)] + ["no-migration"]


def failure_draw(prompt: str, seed: int) -> float:
    """Uniform [0, 1) draw keyed on (seed, prompt) — no RNG state."""
    digest = hashlib.sha256(f"{seed}:{prompt}".encode()).digest()
    return int.from_bytes(digest[:8], "big") / 2.0 ** 64


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fail-rate", type=float, default=0.0)
    ap.add_argument("--garbage", action="store_true")
    ap.add_argument("--hang-s", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    prompt = sys.stdin.read()
    if args.fail_rate > 0.0 and failure_draw(prompt, args.seed) \
            < args.fail_rate:
        if args.hang_s > 0.0:
            # stall, then answer normally: only clients whose timeout is
            # shorter than the hang see a fault (LLMTimeoutError)
            time.sleep(args.hang_s)
        elif args.garbage:
            # parses to an empty shortlist -> LLMMalformedError client-side
            print("I cannot comply with this request.")
            return 0
        else:
            sys.stderr.write("mock_llm: injected crash\n")
            return 17
    print(json.dumps(shortlist(prompt)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
