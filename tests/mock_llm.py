"""Deterministic offline stand-in for an external LLM placement endpoint.

Reads the structured placement prompt (:mod:`repro.core.prompts`) on
stdin and writes an ordered JSON shortlist to stdout — the exact contract
``haf-llm(cmd="...")`` methods and ``python -m repro.launch.serve
--llm-cmd`` expect from a served model, with zero network and zero
randomness: the shortlist is the first K−1 candidate identifiers from the
CANDIDATE ACTIONS list in lexicographic order, hedged with
``no-migration`` (mirroring how the real agents always keep the
no-migration option).

Usage in a sweep (commas in the command are fine — the grammar quotes
them):

    python -m repro.eval --methods \
        'haf-llm(cmd="python tests/mock_llm.py")' --scenarios paper

The same prompt always yields the same shortlist, so sweeps through this
endpoint are reproducible run-to-run — which is what the end-to-end
``haf-llm`` tests pin.
"""
from __future__ import annotations

import json
import re
import sys

CANDIDATE_RE = re.compile(r"mig:s\d+:n\d+->n\d+")
K_RE = re.compile(r"at most (\d+) candidate")


def shortlist(prompt: str) -> list:
    # parse only the candidate section: identifiers quoted in the policy
    # preamble (the example answer) must not leak into the shortlist
    _, _, candidates = prompt.rpartition("CANDIDATE ACTIONS")
    ids = sorted(set(CANDIDATE_RE.findall(candidates)))
    m = K_RE.search(prompt)
    k = int(m.group(1)) if m else 3
    return ids[:max(k - 1, 0)] + ["no-migration"]


def main() -> int:
    print(json.dumps(shortlist(sys.stdin.read())))
    return 0


if __name__ == "__main__":
    sys.exit(main())
