"""End-to-end behaviour tests for the paper's system (Tables II/III shape).

Small workloads (runtime-bounded) — the full-scale numbers live in
benchmarks/ and EXPERIMENTS.md; here we assert the paper's *qualitative*
claims hold end to end:
  H1 (Table III): HAF beats the static placement by fixing the binding
      large-AI consolidation with a large-AI migration.
  H2 (Table II):  the critic prunes migrations and never hurts a noisy
      agent; it approves the decisive early migration.
  H3 (Fig. 2):    the HAF advantage shrinks at ρ=1.25 (capacity-limited).
"""
import pathlib

import numpy as np
import pytest

from repro.core import HAFPlacement, make_agent
from repro.core.critic import Critic
from repro.sim import (Simulator, WorkloadConfig, generate_workload,
                       paper_scenario)
from repro.sim.engine import DeadlineAwareAllocation, StaticPlacement
from repro.sim.types import InstanceCategory

CRITIC_PATH = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / \
    "critic.json"


@pytest.fixture(scope="module")
def scenario():
    return paper_scenario()


@pytest.fixture(scope="module")
def workload(scenario):
    wcfg = WorkloadConfig(rho=1.0, n_ai_requests=1500, seed=0)
    return generate_workload(wcfg, scenario["work_models"])[0]


@pytest.fixture(scope="module")
def critic(scenario):
    if CRITIC_PATH.exists():
        return Critic.load(str(CRITIC_PATH))
    pytest.skip("no trained critic artifact (run benchmarks.critic_data)")


def test_haf_beats_static(scenario, workload):
    sim = Simulator(scenario, epoch_interval=5.0)
    static = sim.run(workload, StaticPlacement(),
                     DeadlineAwareAllocation()).summary()
    haf = sim.run(workload,
                  HAFPlacement(make_agent("qwen3-32b-sim"), critic=None),
                  DeadlineAwareAllocation()).summary()
    assert haf["overall"] > static["overall"] + 0.10
    assert haf["large_ai"] > static["large_ai"] + 0.30
    assert haf["mig_large"] >= 1           # the binding migration happened
    assert static["small_ai"] > 0.95       # small-AI never the bottleneck


def test_critic_gates_noisy_agent(scenario, workload, critic):
    sim = Simulator(scenario, epoch_interval=5.0)
    agent = "deepseek-r1-70b-sim"          # eager/noisy stand-in
    nc = sim.run(workload, HAFPlacement(make_agent(agent), critic=None),
                 DeadlineAwareAllocation()).summary()
    wc = sim.run(workload, HAFPlacement(make_agent(agent), critic=critic),
                 DeadlineAwareAllocation()).summary()
    assert wc["mig_total"] < nc["mig_total"]          # fewer migrations
    assert wc["overall"] >= nc["overall"] - 0.02      # never hurts


def test_critic_approves_decisive_migration(scenario, workload, critic):
    sim = Simulator(scenario, epoch_interval=5.0)
    res = sim.run(workload,
                  HAFPlacement(make_agent("qwen3-32b-sim"), critic=critic),
                  DeadlineAwareAllocation())
    large_migs = [a for _, a in res.migrations
                  if a.category == InstanceCategory.LARGE_AI]
    assert len(large_migs) >= 1
    assert res.summary()["overall"] > 0.85


def test_advantage_shrinks_at_saturation(scenario):
    sim = Simulator(scenario, epoch_interval=5.0)
    gaps = {}
    for rho in (1.0, 1.25):
        wcfg = WorkloadConfig(rho=rho, n_ai_requests=1200, seed=1)
        reqs, _ = generate_workload(wcfg, scenario["work_models"])
        s = sim.run(reqs, StaticPlacement(),
                    DeadlineAwareAllocation()).summary()
        h = sim.run(reqs,
                    HAFPlacement(make_agent("qwen3-32b-sim"), critic=None),
                    DeadlineAwareAllocation()).summary()
        gaps[rho] = h["ai"] - s["ai"]
    assert gaps[1.25] < gaps[1.0]          # capacity-limited convergence
