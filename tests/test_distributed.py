"""Sharding rules, checkpointing, and gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression as comp
from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        spec_for)
from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Just enough of a Mesh for spec_for (shape lookup)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


def test_spec_for_divisible_dims():
    mesh = FakeMesh(data=16, model=16)
    spec = spec_for((152064, 896), ("vocab", "d_model"), mesh)
    assert spec == P("model", "data")


def test_spec_for_fallback_replication():
    mesh = FakeMesh(data=16, model=16)
    # qwen2: 14 heads not divisible by model=16 -> replicated head dim
    spec = spec_for((896, 14, 64), ("d_model", "heads", None), mesh)
    assert spec == P("data",)
    # zamba2: 24 SSD heads not divisible -> replicated
    spec = spec_for((24,), ("ssm_heads",), mesh)
    assert spec == P()


def test_spec_for_no_axis_reuse():
    mesh = FakeMesh(data=16, model=16)
    # both dims want "model": only the first gets it
    spec = spec_for((256, 256), ("vocab", "heads"), mesh)
    assert spec == P("model",)


def test_spec_for_missing_mesh_axis():
    mesh = FakeMesh(data=16, model=16)          # no "pod"
    spec = spec_for((4096, 128), ("batch", None), mesh)
    assert spec == P("data",)
    mesh3 = FakeMesh(pod=2, data=16, model=16)
    spec = spec_for((4096, 128), ("batch", None), mesh3)
    assert spec == P(("pod", "data"),)


def test_checkpoint_roundtrip_and_latest(tmp_path):
    params = {"w": jnp.arange(12.0).reshape(3, 4),
              "nested": {"b": jnp.ones(5, jnp.bfloat16)}}
    ckpt.save_checkpoint(str(tmp_path), 10, params)
    ckpt.save_checkpoint(str(tmp_path), 20, params)
    assert ckpt.latest_step(str(tmp_path)) == 20
    template = {"params": jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)}
    state, step, _ = ckpt.restore_checkpoint(str(tmp_path), template)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(params["w"]))
    assert state["params"]["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    params = {"w": jnp.zeros((4,))}
    ckpt.save_checkpoint(str(tmp_path), 1, params)
    leftovers = [p for p in os.listdir(tmp_path) if p.startswith(".tmp")]
    assert not leftovers


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    template = {"params": {"w": jax.ShapeDtypeStruct((5,), jnp.float32)}}
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(str(tmp_path), template)


def test_checkpoint_extra_state(tmp_path):
    ckpt.save_checkpoint(str(tmp_path), 7, {"w": jnp.zeros(3)},
                         extra={"pipeline": {"step": 7}})
    template = {"params": {"w": jax.ShapeDtypeStruct((3,), jnp.float32)}}
    _, _, extra = ckpt.restore_checkpoint(str(tmp_path), template)
    assert extra == {"pipeline": {"step": 7}}


def test_int8_compression_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    q, scale = comp.compress_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(comp.decompress_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_time():
    """Σ compressed grads → Σ true grads (error feedback carries residual)."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 1e-3)
             for _ in range(50)]
    state = comp.init_state({"g": grads[0]})
    acc = np.zeros(32)
    for g in grads:
        cg, state = comp.compressed_gradients({"g": g}, state)
        acc += np.asarray(cg["g"])
    true = np.sum([np.asarray(g) for g in grads], axis=0)
    resid = np.abs(acc + np.asarray(state.error["g"]) - true)
    assert resid.max() < 1e-4
