"""End-to-end HAF serving run (the paper's headline experiment, reduced).

Runs the 6-node AI-RAN cluster at ρ=1.0 under (i) static placement and
(ii) the full HAF stack, printing the Table-III-style comparison and the
committed migration log.

Run:  PYTHONPATH=src python examples/haf_serving.py [--requests 3000]
"""
import argparse

from repro.core import HAFPlacement, make_agent
from repro.core.critic import Critic, train_critic
from repro.core.datagen import harvest
from repro.sim import (Simulator, WorkloadConfig, generate_workload,
                       paper_scenario)
from repro.sim.engine import DeadlineAwareAllocation, StaticPlacement
import pathlib

CRITIC = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / \
    "critic.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3000)
    ap.add_argument("--rho", type=float, default=1.0)
    args = ap.parse_args()

    sc = paper_scenario()
    reqs, info = generate_workload(
        WorkloadConfig(rho=args.rho, n_ai_requests=args.requests, seed=0),
        sc["work_models"])
    print(f"workload: {len(reqs)} requests over {info['horizon']:.0f}s "
          f"(λ_ai={info['lambda_ai']:.1f}/s)")
    sim = Simulator(sc, epoch_interval=5.0)

    static = sim.run(reqs, StaticPlacement(), DeadlineAwareAllocation())
    print("\nstatic placement:", static.summary())

    if CRITIC.exists():
        critic = Critic.load(str(CRITIC))
    else:
        print("training critic (one-time offline phase)...")
        critic = train_critic(harvest(sc))
        critic.save(str(CRITIC))

    haf = sim.run(reqs, HAFPlacement(make_agent("qwen3-32b-sim"),
                                     critic=critic),
                  DeadlineAwareAllocation())
    print("\nHAF:", haf.summary())
    print("\nmigration log:")
    for t, a in haf.migrations:
        print(f"  t={t:7.1f}s  {a.describe(sc['instances'], sc['nodes'])}")


if __name__ == "__main__":
    main()
