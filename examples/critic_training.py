"""Critic offline training walkthrough (§III-B): exploration + counterfactual
probes → supervised regression → before/after gating comparison.

Run:  PYTHONPATH=src python examples/critic_training.py
(~5 minutes: the harvest replays deterministic counterfactual rollouts.)
"""
from repro.core import HAFPlacement, make_agent, train_critic
from repro.core.datagen import harvest
from repro.sim import (Simulator, WorkloadConfig, generate_workload,
                       paper_scenario)
from repro.sim.engine import DeadlineAwareAllocation


def main() -> None:
    sc = paper_scenario()
    print("1) harvesting epoch samples (bulk exploration + counterfactual "
          "probes)...")
    samples = harvest(sc, verbose=True)
    print(f"   {len(samples)} (φ, r, mask) samples")

    print("2) supervised regression (Eq. 10, factored Δ-critic)...")
    critic = train_critic(samples, epochs=1500, seed=0)

    print("3) gating effect on an erratic agent (deepseek-r1 stand-in):")
    reqs, _ = generate_workload(
        WorkloadConfig(rho=1.0, n_ai_requests=2500, seed=0),
        sc["work_models"])
    sim = Simulator(sc, epoch_interval=5.0)
    for critic_arg, tag in ((None, "HAF-NoCritic"), (critic, "HAF(+Critic)")):
        pol = HAFPlacement(make_agent("deepseek-r1-70b-sim"),
                           critic=critic_arg)
        s = sim.run(reqs, pol, DeadlineAwareAllocation()).summary()
        print(f"   {tag:14s} overall={s['overall']:.3f} "
              f"migrations={s['mig_large']}/{s['mig_total']}")


if __name__ == "__main__":
    main()
