"""Train a ~100M-param member of the assigned-architecture family for a few
hundred steps with the fault-tolerant loop (checkpoints + injected failure).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
(~100M params on CPU: a few minutes; use --steps 50 for a quick pass.)
"""
import argparse
import tempfile

from repro.data.pipeline import DataPipeline
from repro.distributed.failure import FailureInjector
from repro.launch.train import preset_config
from repro.models.api import Model
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step")
    args = ap.parse_args()

    cfg = preset_config(args.arch, "100m")
    model = Model(cfg, remat="none")
    print(f"{cfg.name}: {model.param_count()/1e6:.1f}M params")
    pipe = DataPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        global_batch=args.batch)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=ckpt_dir, log_every=20)
        injector = FailureInjector(
            [args.fail_at] if args.fail_at else [args.steps // 2])
        hist = train(model, pipe, tc, injector=injector)
    print(f"\nloss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"restarts at {hist['restarts']}; "
          f"stragglers flagged: {len(hist['stragglers'])}")


if __name__ == "__main__":
    main()
