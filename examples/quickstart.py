"""Quickstart: the three layers of the repo in ~60 seconds on CPU.

  1. the paper's closed-form deadline-aware allocator (one node),
  2. one HAF placement decision end to end (prompt → agent → critic),
  3. one assigned architecture doing a train step + a decode step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------- #
# 1) Eq. 16–19: allocate one node's GPU between a DU (floored) and 2 AIs
# --------------------------------------------------------------------- #
from repro.core.allocator import solve_resource

psi = jnp.asarray([2e12, 6e13, 2.4e14])        # DU, small-AI, large-AI work
omega = jnp.asarray([900.0, 12.0, 40.0])       # urgency (1ms vs seconds)
floors = jnp.asarray([3e13, 0.0, 0.0])         # DU floor from Eq. 15
res = solve_resource(psi, omega, floors, jnp.asarray(2e14))
print("allocator: g* =", np.round(np.asarray(res.alloc) / 1e12, 1),
      "TFLOP/s  (DU pinned at floor:", bool(res.floored[0]), ")")

# --------------------------------------------------------------------- #
# 2) one placement epoch: snapshot -> prompt -> agent -> critic -> action
# --------------------------------------------------------------------- #
from repro.core import HAFPlacement, candidate_actions, make_agent
from repro.core.prompts import build_prompt
from repro.sim import (Simulator, WorkloadConfig, generate_workload,
                       paper_scenario)
from repro.sim.engine import DeadlineAwareAllocation, StaticPlacement

sc = paper_scenario()
reqs, _ = generate_workload(
    WorkloadConfig(rho=1.0, n_ai_requests=400, seed=0), sc["work_models"])
snaps = []
Simulator(sc).run(reqs, StaticPlacement(), DeadlineAwareAllocation(),
                  epoch_hook=lambda rec, cl: snaps.append(rec.snapshot))
snap = snaps[1]
cands = candidate_actions(snap)
print(f"\nplacement: |M_k| = {len(cands)} candidates; prompt excerpt:")
print("\n".join(build_prompt(snap, cands).splitlines()[:6]), "...")
agent = make_agent("qwen3-32b-sim")
decision = HAFPlacement(agent, critic=None).decide(snap)
print("agent decision:",
      decision.describe(sc["instances"], sc["nodes"]) if decision
      else "no-migration")

# --------------------------------------------------------------------- #
# 3) one assigned architecture: train step + decode step (reduced config)
# --------------------------------------------------------------------- #
from repro.configs import ShapeCell, smoke_config
from repro.models.api import Model

cfg = smoke_config("deepseek-v2-lite-16b")      # MLA + MoE family
model = Model(cfg, remat="none")
params = model.init(jax.random.PRNGKey(0))
batch = model.make_inputs(ShapeCell("demo", 16, 2, "train"),
                          jax.random.PRNGKey(1))
loss, grads = jax.value_and_grad(model.loss)(params, batch)
print(f"\n{cfg.name}: loss={float(loss):.3f}, "
      f"params={model.param_count()/1e6:.2f}M")
logits, cache = model.prefill(params, {"tokens": batch["tokens"][:, :8]})
cache = model.pad_cache(cache, 16)
logits, cache = model.decode_step(
    params, cache, {"tokens": batch["tokens"][:, 8:9],
                    "pos": jnp.asarray(8, jnp.int32)})
print("decode step ok:", logits.shape)
