"""Scenario-generation + fleet-evaluation demo.

Builds three generated scenarios (a bursty flash-crowd, a fault-injected
node-outage, and a 12-node dense-urban topology), then sweeps two
placement policies over them with two workload seeds each — in parallel —
and prints the aggregated per-class fulfillment table.

  PYTHONPATH=src python examples/scenario_sweep.py
"""
from __future__ import annotations

import pathlib

from repro.eval import SweepSpec, build_report, format_table, run_sweep, \
    write_report
from repro.sim.scenarios import make_scenario, scenario_fingerprint

OUT = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / \
    "scenario_sweep_demo.json"


def main() -> None:
    # 1) scenarios are data: inspect one before running anything
    sc = make_scenario("flash-crowd", seed=0, magnitude=6.0)
    print(f"flash-crowd: {len(sc['nodes'])} nodes, "
          f"{len(sc['instances'])} instances, "
          f"spike windows={sc['workload']['arrival']['windows']}")
    print(f"fingerprint: {scenario_fingerprint(sc)[:16]}... "
          f"(same seed -> same fingerprint)")

    # 2) declare the sweep: policies x scenarios x seeds
    spec = SweepSpec(
        methods=("haf-static", "round-robin"),
        scenarios=(
            {"family": "flash-crowd", "params": {"magnitude": 6.0}},
            "node-outage",
            {"family": "dense-urban", "params": {"n_nodes": 12}},
        ),
        seeds=(0, 1),
        n_ai_requests=400,          # demo-sized; drop for the real run
        workers=2,
    )

    # 3) run it (each job is an independent simulator run in a worker)
    rows = run_sweep(spec, verbose=True)

    # 4) aggregate into mean/CI cells and persist the JSON report
    report = build_report(spec, rows)
    print(format_table(report["aggregate"]))
    write_report(report, OUT)
    print(f"report -> {OUT}")


if __name__ == "__main__":
    main()
