"""Declarative experiment demo: spec grammar, provenance, resume.

Declares a fleet sweep (two placement policies over three generated
scenarios × two workload seeds) as a :class:`repro.exp.ExperimentSpec` —
methods and scenarios in the spec grammar — writes it to a TOML file,
runs it through the provenance-stamped harness, then runs it AGAIN to
show resume: every completed row is reused from the report on disk.

  PYTHONPATH=src python examples/scenario_sweep.py
"""
from __future__ import annotations

import pathlib

from repro.eval import format_table
from repro.exp import ExperimentSpec, run_experiment
from repro.sim.scenarios import make_scenario, scenario_fingerprint

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
OUT = ART / "scenario_sweep_demo.json"
SPEC_FILE = ART / "scenario_sweep_demo.toml"


def main() -> None:
    # 1) scenarios are data: inspect one before running anything
    sc = make_scenario("flash-crowd", seed=0, magnitude=6.0)
    print(f"flash-crowd: {len(sc['nodes'])} nodes, "
          f"{len(sc['instances'])} instances, "
          f"spike windows={sc['workload']['arrival']['windows']}")
    print(f"fingerprint: {scenario_fingerprint(sc)[:16]}... "
          f"(same seed -> same fingerprint)")

    # 2) experiments are data too: the whole sweep in one spec, with the
    #    method/scenario grammar every frontend shares
    spec = ExperimentSpec(
        name="scenario-sweep-demo",
        methods=("haf-static", "round-robin"),
        scenarios=("flash-crowd(magnitude=6.0)",
                   "node-outage",
                   "dense-urban(n_nodes=12)"),
        seeds=(0, 1),
        n_ai_requests=400,          # demo-sized; drop for the real run
        workers=2,
        out=str(OUT))
    spec.to_file(SPEC_FILE)         # checked-in form: --spec runs it too
    print(f"spec -> {SPEC_FILE}  (spec_hash={spec.spec_hash()[:12]}, "
          f"run it with: python -m repro.eval --spec {SPEC_FILE})")

    # 3) run it (parallel workers; the report embeds the canonical spec,
    #    its hashes, per-cell scenario fingerprints and backend info)
    OUT.unlink(missing_ok=True)
    report = run_experiment(spec, verbose=True)
    print(format_table(report["aggregate"]))
    print(f"report -> {OUT}")

    # 4) run it AGAIN: the resume key matches, every row is reused
    report = run_experiment(spec, verbose=True)
    print(f"second run resumed "
          f"{report['provenance']['resumed_rows']}/{report['n_runs']} rows "
          "from the report on disk")


if __name__ == "__main__":
    main()
